"""Distributed (per-process agent-slice) checkpoints with a two-phase
rank-0 commit.

Lifting the old "no checkpointing on a mesh spanning processes" ban
needs a layout where no process ever has to materialize the global
carry: each process writes only the contiguous agent block its local
devices hold (the ``runtime.shard_agent_tree`` tiling) as a complete
mini-checkpoint with its own integrity manifest, and rank 0 turns the
pile of slices into a checkpoint *atomically* with a commit marker.

On-disk layout of one step::

    <dir>/step_<n>/
        agents-00000-00002/      # process A's rows [0, 2): leaf .npy files
            ...  manifest.json   #   + per-slice integrity manifest
        agents-00002-00004/      # process B's rows [2, 4)
        replicated/              # rank 0 only: non-agent leaves (round, key)
        COMMIT                   # rank 0, written LAST: the step's metadata

Two-phase protocol: (prepare) every process writes its slice into a
``.tmp-*`` dir and renames it into place; rank 0 additionally writes
``replicated/``, then polls until the renamed slices verify and tile
``[0, n_agents)`` exactly, and only then (commit) renames ``COMMIT``
into place. A host dying mid-write therefore leaves either a missing
slice or a missing ``COMMIT`` — never a torn checkpoint:
``restore_latest`` treats any step without a verifying ``COMMIT`` as
garbage, skips it (optionally deleting it), and falls back to the
previous committed step. A *fully prepared* step whose rank 0 died
between prepare and commit can be completed by any survivor via
:meth:`DistributedCheckpointManager.finalize_pending` (prepare is
complete, so the commit is unambiguous — the recovery supervisor does
this before re-bootstrapping).

Restore is elastic: the saved slice count need not match the reading
mesh. :func:`read_step_mesh` builds each global array with
``jax.make_array_from_callback``, mapping every new shard's rows back
to saved slices by range intersection — the ownership mapping is the
``fault.ElasticPlan`` even tiling, and the plan is emitted as a
``restore_reshard`` telemetry event so an elastic restart is auditable.
:func:`read_step_host` assembles full host arrays for the loop driver
and cross-format restores.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager, step_dir

COMMIT = "COMMIT"
REPLICATED = "replicated"
_SLICE_RE = re.compile(r"^agents-(\d+)-(\d+)$")


# ---------------------------------------------------------------------------
# Leaf classification + local-slice extraction
# ---------------------------------------------------------------------------
def is_agent_sharded(leaf) -> bool:
    """True for jax.Arrays actually split over devices along axis 0 —
    the agent-stacked carry leaves on a >1-shard mesh. Host numpy,
    python scalars, single-device and fully-replicated arrays all fall
    into the ``replicated/`` group (rank 0 writes them once)."""
    return (isinstance(leaf, jax.Array) and leaf.ndim >= 1
            and hasattr(leaf, "sharding")
            and not leaf.sharding.is_fully_replicated)


def local_block(leaf) -> Tuple[np.ndarray, int, int]:
    """This process's contiguous rows of an agent-sharded array:
    ``(block, lo, hi)`` with ``block == leaf[lo:hi]``."""
    def start(s):
        idx = s.index[0] if s.index else slice(None)
        return idx.start or 0

    shards = sorted(leaf.addressable_shards, key=start)
    lo = start(shards[0])
    rows = []
    nxt = lo
    for s in shards:
        data = np.asarray(s.data)
        assert start(s) == nxt, \
            f"non-contiguous local shards at row {start(s)} (expected {nxt})"
        rows.append(data)
        nxt += data.shape[0]
    return np.concatenate(rows, axis=0), lo, nxt


def _slice_name(lo: int, hi: int) -> str:
    return f"agents-{lo:05d}-{hi:05d}"


def slice_dirs(d: str) -> List[Tuple[int, int, str]]:
    """Renamed-into-place slice dirs of one step: ``[(lo, hi, path)]``
    sorted by ``lo`` (``.tmp-*`` prepares are excluded by name)."""
    out = []
    for name in os.listdir(d) if os.path.isdir(d) else []:
        m = _SLICE_RE.match(name)
        if m:
            out.append((int(m.group(1)), int(m.group(2)),
                        os.path.join(d, name)))
    return sorted(out)


# ---------------------------------------------------------------------------
# Low-level writers (unit-testable without jax.distributed)
# ---------------------------------------------------------------------------
def write_slice(d: str, block_tree, lo: int, hi: int, n_agents: int, *,
                step: int, tag: str = "w", on_phase=None) -> str:
    """Prepare one agent slice: write ``block_tree`` (host arrays of rows
    ``[lo, hi)``) into ``.tmp-*`` and rename into place. Returns the
    slice path."""
    tmp = os.path.join(d, f".tmp-{_slice_name(lo, hi)}-{tag}")
    final = os.path.join(d, _slice_name(lo, hi))
    shutil.rmtree(tmp, ignore_errors=True)
    ckpt.save(tmp, block_tree, step=step,
              extra={"agents": [lo, hi], "n_agents": n_agents},
              on_phase=on_phase)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    return final


def write_replicated(d: str, rep_tree, *, step: int,
                     extra: Optional[dict] = None, on_phase=None) -> str:
    """Prepare the rank-0 replicated group (carries the user ``extra``)."""
    tmp = os.path.join(d, ".tmp-" + REPLICATED)
    final = os.path.join(d, REPLICATED)
    shutil.rmtree(tmp, ignore_errors=True)
    ckpt.save(tmp, rep_tree, step=step, extra={"user": extra or {}},
              on_phase=on_phase)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    return final


def build_commit_meta(d: str, *, expect_n: Optional[int] = None,
                      verify: bool = True) -> Optional[dict]:
    """The COMMIT metadata for a *fully prepared* step dir, or None if
    prepare is incomplete: the replicated group must verify, every slice
    must verify, and the slices must tile ``[0, n_agents)`` exactly."""
    rep = os.path.join(d, REPLICATED)
    repm = ckpt.load_manifest(rep)
    if repm is None or (verify and not ckpt.is_valid(rep)):
        return None
    slices = slice_dirs(d)
    n_agents, sharded = 0, []
    if slices:
        first = ckpt.load_manifest(slices[0][2])
        if first is None:
            return None
        n_agents = int(first["extra"].get("n_agents", 0))
        if expect_n is not None and n_agents != expect_n:
            return None
        sharded = sorted(e["name"] for e in first["leaves"])
        nxt = 0
        for lo, hi, path in slices:
            if lo != nxt:
                return None              # gap or overlap in the tiling
            m = ckpt.load_manifest(path)
            if m is None or m["extra"].get("agents") != [lo, hi] \
                    or sorted(e["name"] for e in m["leaves"]) != sharded \
                    or (verify and not ckpt.is_valid(path)):
                return None
            nxt = hi
        if nxt != n_agents:
            return None
    elif expect_n:
        return None
    return {"step": int(repm["step"]), "n_agents": n_agents,
            "slices": [[lo, hi] for lo, hi, _ in slices],
            "sharded": sharded,
            "replicated": sorted(e["name"] for e in repm["leaves"]),
            "extra": dict(repm.get("extra", {}).get("user", {}))}


def write_commit(d: str, meta: dict) -> None:
    tmp = os.path.join(d, ".tmp-" + COMMIT)
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, os.path.join(d, COMMIT))


# ---------------------------------------------------------------------------
# Readers
# ---------------------------------------------------------------------------
def is_distributed_dir(d: str) -> bool:
    return (os.path.exists(os.path.join(d, COMMIT))
            or os.path.isdir(os.path.join(d, REPLICATED))
            or bool(slice_dirs(d)))


def committed_meta(d: str, *, verify: bool = True) -> Optional[dict]:
    """The COMMIT metadata iff the step is committed AND (``verify``)
    every referenced manifest still checks out — a corrupted committed
    step reads as uncommitted and is skipped."""
    try:
        with open(os.path.join(d, COMMIT)) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not verify:
        return meta
    try:
        rebuilt = build_commit_meta(d, expect_n=meta.get("n_agents"))
    except (KeyError, TypeError, ValueError):
        return None
    if rebuilt is None or rebuilt["slices"] != meta.get("slices") \
            or rebuilt["sharded"] != meta.get("sharded"):
        return None
    return meta


class SliceReader:
    """Row-range reads across a committed step's slices, with the loaded
    arrays cached per ``(slice, leaf)`` so a restore touches each file
    once."""

    def __init__(self, d: str, meta: dict):
        self.dir = d
        self.meta = meta
        self.slices = slice_dirs(d)
        self._cache: Dict[Tuple[str, str], np.ndarray] = {}
        self._manifests: Dict[str, Optional[dict]] = {}

    def _slice_array(self, path: str, name: str) -> np.ndarray:
        key = (path, name)
        if key not in self._cache:
            if path not in self._manifests:
                self._manifests[path] = ckpt.load_manifest(path)
            self._cache[key] = ckpt.load_array(
                path, name, self._manifests[path])
        return self._cache[key]

    def rows(self, name: str, start: int, stop: int) -> np.ndarray:
        """Rows ``[start, stop)`` of the sharded leaf ``name``, assembled
        from every saved slice the range intersects (the ElasticPlan
        ownership mapping run backwards)."""
        parts = []
        for lo, hi, path in self.slices:
            a, b = max(lo, start), min(hi, stop)
            if a >= b:
                continue
            parts.append(self._slice_array(path, name)[a - lo:b - lo])
        out = np.concatenate(parts, axis=0) if parts else \
            np.zeros((0,), np.float32)
        assert out.shape[0] == stop - start, \
            f"{name}: rows [{start},{stop}) not covered by slices"
        return out

    def replicated(self, name: str) -> np.ndarray:
        return ckpt.load_array(os.path.join(self.dir, REPLICATED), name)


def _target_leaves(target_tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    return [(ckpt.leaf_name(path) + ".npy", leaf) for path, leaf in flat], \
        treedef


def read_step_host(d: str, target_tree, *, meta: Optional[dict] = None):
    """Restore a committed step into host/global arrays shaped like
    ``target_tree`` (names absent from the target — e.g. ``reports`` —
    are simply not read). Returns ``(tree, step)``."""
    meta = meta if meta is not None else committed_meta(d)
    if meta is None:
        raise ValueError(f"{d}: not a committed distributed checkpoint")
    reader = SliceReader(d, meta)
    sharded = set(meta["sharded"])
    named, treedef = _target_leaves(target_tree)
    out = []
    for name, leaf in named:
        if name in sharded:
            arr = reader.rows(name, 0, meta["n_agents"])
        else:
            arr = reader.replicated(name)
        if not hasattr(leaf, "shape"):       # python scalar leaf (round)
            out.append(type(leaf)(arr))
            continue
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{name}: ckpt {arr.shape} vs target {leaf.shape}"
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), int(meta["step"])


def read_step_mesh(d: str, target_tree, mesh, *,
                   meta: Optional[dict] = None, telemetry=None):
    """Restore a committed step directly onto ``mesh`` — each process
    loads only the rows its local devices own, so a checkpoint written
    by P processes / S shards restores onto any other process/shard
    count. Returns ``(tree, step)`` of global jax.Arrays."""
    from repro.distributed import fault, runtime as runtime_lib
    meta = meta if meta is not None else committed_meta(d)
    if meta is None:
        raise ValueError(f"{d}: not a committed distributed checkpoint")
    reader = SliceReader(d, meta)
    sharded = set(meta["sharded"])
    n_agents = int(meta["n_agents"])
    new_shards = int(mesh.devices.size)
    if sharded and n_agents:
        old = max(1, len(meta["slices"]))
        plan = fault.ElasticPlan(
            n_agents=n_agents, old_shards=old, new_shards=new_shards,
            dead=(), survivors=tuple(range(old)))
        if telemetry is not None:
            telemetry.emit("restore_reshard", step=int(meta["step"]),
                           n_agents=n_agents, old_shards=plan.old_shards,
                           new_shards=plan.new_shards,
                           slices=meta["slices"])
    agent_sh = runtime_lib.agent_sharding(mesh)
    rep_sh = runtime_lib.replicated_sharding(mesh)
    named, treedef = _target_leaves(target_tree)
    out = []
    for name, leaf in named:
        if not hasattr(leaf, "shape"):
            out.append(type(leaf)(reader.replicated(name)))
            continue
        shape, dtype = tuple(leaf.shape), leaf.dtype
        if name in sharded:
            def cb(idx, name=name, dtype=dtype):
                rows = reader.rows(name, idx[0].start or 0,
                                   idx[0].stop if idx[0].stop is not None
                                   else n_agents)
                return np.asarray(rows[(slice(None),) + tuple(idx[1:])],
                                  dtype=dtype)
            out.append(jax.make_array_from_callback(shape, agent_sh, cb))
        else:
            arr = np.asarray(reader.replicated(name), dtype=dtype)
            assert arr.shape == shape, \
                f"{name}: ckpt {arr.shape} vs target {shape}"
            out.append(jax.make_array_from_callback(
                shape, rep_sh, lambda idx, arr=arr: arr[idx]))
    return jax.tree_util.tree_unflatten(treedef, out), int(meta["step"])


# ---------------------------------------------------------------------------
# The manager
# ---------------------------------------------------------------------------
class DistributedCheckpointManager(CheckpointManager):
    """Per-process slice writer + rank-0 two-phase committer.

    Every process calls ``save(step, tree)`` with the *same* step and the
    mesh-sharded tree; each writes its own slice, rank 0 writes the
    replicated group and commits once every slice verifies. Single
    process (or a tree with no sharded leaves) degenerates to one slice
    — the format is identical, so single- and multi-process runs share
    checkpoints."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True, process_id: int = 0,
                 primary: Optional[bool] = None,
                 commit_timeout_s: float = 60.0, poll_s: float = 0.05,
                 telemetry=None):
        super().__init__(directory, keep=keep, async_write=async_write)
        self.process_id = process_id
        self.primary = (process_id == 0) if primary is None else primary
        self.commit_timeout_s = commit_timeout_s
        self.poll_s = poll_s
        self.telemetry = telemetry
        # clean slice prepares a crashed writer left inside step dirs
        for s in self.steps():
            d = step_dir(directory, s)
            for name in os.listdir(d):
                if name.startswith(".tmp-"):
                    shutil.rmtree(os.path.join(d, name), ignore_errors=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        payload = self._snapshot(tree)
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, payload, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, payload, extra)

    def _snapshot(self, tree) -> dict:
        """Caller-thread device→host copy: local rows of sharded leaves,
        full values of replicated ones."""
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        shard_blocks: Dict[str, np.ndarray] = {}
        replicated: Dict[str, object] = {}
        lo = hi = n = None
        for path, leaf in flat:
            name = ckpt.leaf_name(path)
            if is_agent_sharded(leaf):
                blk, blo, bhi = local_block(leaf)
                if lo is None:
                    lo, hi, n = blo, bhi, int(leaf.shape[0])
                else:
                    assert (blo, bhi, int(leaf.shape[0])) == (lo, hi, n), \
                        f"{name}: mixed agent shardings in one checkpoint"
                shard_blocks[name] = blk
            else:
                replicated[name] = jax.device_get(leaf) \
                    if isinstance(leaf, jax.Array) else leaf
        return {"sharded": shard_blocks, "replicated": replicated,
                "lo": lo, "hi": hi, "n": n}

    def _write(self, step: int, payload, extra):
        d = step_dir(self.directory, step)
        os.makedirs(d, exist_ok=True)
        self._phase(step, "write_begin", d)
        if payload["sharded"]:
            # rewriting a step saved under an older shard layout: drop
            # stale slices overlapping our range before preparing ours
            for lo, hi, path in slice_dirs(d):
                if lo < payload["hi"] and hi > payload["lo"] and \
                        (lo, hi) != (payload["lo"], payload["hi"]):
                    shutil.rmtree(path, ignore_errors=True)
            write_slice(d, payload["sharded"], payload["lo"], payload["hi"],
                        payload["n"], step=step, tag=f"p{self.process_id}",
                        on_phase=lambda ph: self._phase(step, ph, d))
        self._phase(step, "prepared", d)
        if not self.primary:
            return
        # a stale COMMIT (step being rewritten after an elastic restart)
        # must drop before the new prepare completes
        try:
            os.remove(os.path.join(d, COMMIT))
        except OSError:
            pass
        write_replicated(d, payload["replicated"], step=step, extra=extra,
                         on_phase=lambda ph: self._phase(step, ph, d))
        if self._await_commit(d, step, payload["n"]):
            self._phase(step, "committed", d)
            self._rotate()
        else:
            if self.telemetry is not None:
                self.telemetry.emit("ckpt_commit_timeout", step=step,
                                    timeout_s=self.commit_timeout_s)

    def _await_commit(self, d: str, step: int, expect_n) -> bool:
        """Phase two: poll until every peer's slice is prepared and
        verifies, then write COMMIT. False on timeout (a peer died
        mid-prepare — the step stays uncommitted, restore skips it)."""
        deadline = time.monotonic() + self.commit_timeout_s
        while True:
            meta = build_commit_meta(d, expect_n=expect_n)
            if meta is not None:
                self._phase(step, "pre_commit", d)
                write_commit(d, meta)
                return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(self.poll_s)

    def _rotate(self):
        if self.primary:
            super()._rotate()

    # -- restore ------------------------------------------------------------
    def latest_committed(self) -> int:
        """Newest committed-and-verifying step, or -1."""
        for s in reversed(self.steps()):
            if committed_meta(step_dir(self.directory, s)) is not None:
                return s
        return -1

    def restore_latest(self, target_tree, *, mesh=None, gc: bool = True,
                       shardings=None):
        """(tree, step) from the newest *committed* step; uncommitted or
        unverifiable newer steps are skipped and (``gc``, rank 0 only)
        deleted. ``mesh``: restore directly onto a device mesh instead
        of host arrays."""
        self.wait()
        for s in reversed(self.steps()):
            d = step_dir(self.directory, s)
            meta = committed_meta(d)
            if meta is None:
                if gc and self.primary:
                    shutil.rmtree(d, ignore_errors=True)
                continue
            self.last_extra = dict(meta.get("extra") or {})
            if mesh is not None:
                return read_step_mesh(d, target_tree, mesh, meta=meta,
                                      telemetry=self.telemetry)
            return read_step_host(d, target_tree, meta=meta)
        return None, -1

    def restore_step(self, step: int, target_tree, *, mesh=None,
                     shardings=None):
        self.wait()
        d = step_dir(self.directory, step)
        meta = committed_meta(d) if os.path.isdir(d) else None
        if meta is None:
            return None, -1
        self.last_extra = dict(meta.get("extra") or {})
        if mesh is not None:
            return read_step_mesh(d, target_tree, mesh, meta=meta,
                                  telemetry=self.telemetry)
        return read_step_host(d, target_tree, meta=meta)

    # -- recovery -----------------------------------------------------------
    def finalize_pending(self) -> Optional[int]:
        """Commit takeover: complete the newest fully-prepared step whose
        writer died between prepare and commit. Safe because prepare
        completeness is checkable (slices verify + tile exactly) and the
        commit content is a pure function of the prepared files. Returns
        the finalized step, or None if nothing was pending."""
        self.wait()
        for s in reversed(self.steps()):
            d = step_dir(self.directory, s)
            if committed_meta(d) is not None:
                return None              # newest usable step already committed
            meta = build_commit_meta(d)
            if meta is not None:
                write_commit(d, meta)
                if self.telemetry is not None:
                    self.telemetry.emit("ckpt_finalized", step=s)
                return s
        return None
