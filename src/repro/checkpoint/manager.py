"""Checkpoint manager: atomic directories, async writes, rotation,
restore-latest-valid.

Atomicity: write into ``<dir>/tmp.<step>`` then ``os.rename`` to
``step_<n>`` — a crash mid-write leaves only a tmp dir that is ignored and
garbage-collected. Async: the device→host copy happens on the caller
thread (cheap, and pins the values), the disk write on a worker thread so
training overlaps I/O. A write failure on the worker thread (disk full,
rename failure, injected fault) is captured and re-raised on the next
``save()``/``wait()`` call — training never silently continues
uncheckpointed. Restore scans descending steps and returns the first
checkpoint whose integrity manifest verifies; it understands both the
flat single-file layout and the distributed per-slice layout
(:mod:`repro.checkpoint.distributed`), so a run can move between the
loop and sharded drivers across restarts.

Fault-injection surface: ``self.hooks`` (when set, e.g. by
``distributed.chaos.FaultSchedule.checkpoint_phase``) is called as
``hooks(step, phase, directory)`` at every write phase —
``write_begin`` → ``leaves_written`` → ``prepared`` → ``committed``.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Callable, Optional

import jax

from repro.checkpoint import ckpt

_STEP_RE = re.compile(r"^step_(\d+)$")


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step}")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # chaos/fault-injection hook: hooks(step, phase, directory)
        self.hooks: Optional[Callable[[int, str, str], None]] = None
        # manifest "extra" dict of the step most recently restored
        self.last_extra: dict = {}
        os.makedirs(directory, exist_ok=True)
        # clean stale tmp dirs from crashed runs
        for d in os.listdir(directory):
            if d.startswith("tmp."):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    def _phase(self, step: int, phase: str, directory: str):
        if self.hooks is not None:
            self.hooks(step, phase, directory)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        self.wait()                      # joins + re-raises a prior failure
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_tree, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra)

    def _write_guarded(self, step: int, host_tree, extra):
        try:
            self._write(step, host_tree, extra)
        except BaseException as e:  # noqa: BLE001 - resurface on caller thread
            self._error = e

    def _write(self, step: int, host_tree, extra):
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = step_dir(self.directory, step)
        shutil.rmtree(tmp, ignore_errors=True)
        self._phase(step, "write_begin", tmp)
        ckpt.save(tmp, host_tree, step=step, extra=extra,
                  on_phase=lambda ph: self._phase(step, ph, tmp))
        self._phase(step, "prepared", tmp)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._phase(step, "committed", final)
        self._rotate()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _rotate(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(step_dir(self.directory, s), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _restore_dir(self, d: str, target_tree, *, shardings=None):
        """Restore from one step dir, dispatching on its on-disk format;
        None if the dir is torn/unverifiable."""
        from repro.checkpoint import distributed
        if distributed.is_distributed_dir(d):
            meta = distributed.committed_meta(d)
            if meta is None:
                return None
            tree, step = distributed.read_step_host(d, target_tree, meta=meta)
            self.last_extra = dict(meta.get("extra") or {})
            return tree, step
        if not ckpt.is_valid(d):
            return None
        tree, step = ckpt.restore(d, target_tree, shardings=shardings)
        manifest = ckpt.load_manifest(d) or {}
        self.last_extra = dict(manifest.get("extra") or {})
        return tree, step

    def restore_latest(self, target_tree, *, shardings=None):
        """Returns (tree, step) from the newest checkpoint that passes the
        integrity check; (None, -1) if none exists."""
        self.wait()
        for s in reversed(self.steps()):
            got = self._restore_dir(step_dir(self.directory, s), target_tree,
                                    shardings=shardings)
            if got is not None:
                return got
        return None, -1

    def restore_step(self, step: int, target_tree, *, shardings=None):
        """Restore a specific step (both layouts); (None, -1) when the step
        is absent or fails verification."""
        self.wait()
        d = step_dir(self.directory, step)
        if not os.path.isdir(d):
            return None, -1
        got = self._restore_dir(d, target_tree, shardings=shardings)
        return got if got is not None else (None, -1)
