"""Checkpoint manager: atomic directories, async writes, rotation,
restore-latest-valid.

Atomicity: write into ``<dir>/tmp.<step>`` then ``os.rename`` to
``step_<n>`` — a crash mid-write leaves only a tmp dir that is ignored and
garbage-collected. Async: the device→host copy happens on the caller
thread (cheap, and pins the values), the disk write on a worker thread so
training overlaps I/O. Restore scans descending steps and returns the
first checkpoint whose integrity manifest verifies.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Optional

import jax

from repro.checkpoint import ckpt

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # clean stale tmp dirs from crashed runs
        for d in os.listdir(directory):
            if d.startswith("tmp."):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra)

    def _write(self, step: int, host_tree, extra):
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        ckpt.save(tmp, host_tree, step=step, extra=extra)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._rotate()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore_latest(self, target_tree, *, shardings=None):
        """Returns (tree, step) from the newest checkpoint that passes the
        integrity check; (None, -1) if none exists."""
        self.wait()
        for s in reversed(self.steps()):
            d = os.path.join(self.directory, f"step_{s}")
            if ckpt.is_valid(d):
                return ckpt.restore(d, target_tree, shardings=shardings)
        return None, -1
