"""Sharding-aware checkpointing: atomic save, integrity manifest, rotation,
async writes, restore-with-reshard for elastic restarts."""
from repro.checkpoint import ckpt, manager  # noqa: F401
