"""Sharding-aware checkpointing: atomic save, integrity manifest, rotation,
async writes, restore-with-reshard for elastic restarts, and the
distributed per-process-slice layout with a two-phase rank-0 commit."""
from repro.checkpoint import ckpt, distributed, manager  # noqa: F401
