"""Pytree ↔ disk serialization with an integrity manifest.

Layout: one ``.npy`` per leaf (path-encoded filename) + ``manifest.json``
holding the treedef, shapes, dtypes, per-file sha256 and the step. A
checkpoint is valid iff the manifest exists and every digest matches —
half-written checkpoints (killed node) are detected and skipped by the
manager. Restore accepts a sharding tree so a checkpoint written on one
mesh can be loaded onto another (elastic restart).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Callable, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def leaf_name(path) -> str:
    """Public alias for the path→filename encoding (distributed layout
    reuses it for by-name leaf addressing)."""
    return _leaf_name(path)


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def _sha256(fn: str) -> str:
    h = hashlib.sha256()
    with open(fn, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(directory: str, tree, *, step: int = 0, extra: Optional[dict] = None,
         on_phase: Optional[Callable[[str], None]] = None):
    """``on_phase`` (if given) is called with ``"leaves_written"`` after
    every leaf file landed but *before* the manifest — the window where a
    crash leaves an unverifiable (and therefore skipped) checkpoint."""
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    entries = []
    for path, leaf in leaves:
        name = _leaf_name(path) + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        store = arr
        if arr.dtype.kind not in "biufc":
            # ml_dtypes (bf16/fp8) aren't native npy dtypes — store raw
            # bits as a same-width uint; the manifest keeps the true dtype.
            store = arr.view(f"u{arr.dtype.itemsize}")
        np.save(os.path.join(directory, name), store)
        entries.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": _sha256(os.path.join(directory, name)),
        })
    if on_phase is not None:
        on_phase("leaves_written")
    manifest = {"step": step, "leaves": entries, "extra": extra or {}}
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)


def load_manifest(directory: str) -> Optional[dict]:
    """The parsed manifest, or None when missing/corrupt."""
    try:
        with open(os.path.join(directory, MANIFEST)) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def load_array(directory: str, name: str,
               manifest: Optional[dict] = None) -> np.ndarray:
    """One leaf by manifest ``name`` (``<leaf>.npy``), with the uint
    bit-pattern view undone back to the true (bf16/fp8) dtype."""
    manifest = manifest if manifest is not None else load_manifest(directory)
    dtypes = {e["name"]: e["dtype"] for e in (manifest or {}).get("leaves", [])}
    arr = np.load(os.path.join(directory, name))
    true_dt = dtypes.get(name)
    if true_dt is not None and arr.dtype.kind == "u" \
            and true_dt != str(arr.dtype):
        import ml_dtypes  # noqa: F401  (registers bf16/fp8 dtypes)
        arr = arr.view(np.dtype(true_dt))
    return arr


def is_valid(directory: str) -> bool:
    mf = os.path.join(directory, MANIFEST)
    if not os.path.exists(mf):
        return False
    try:
        manifest = json.load(open(mf))
        for e in manifest["leaves"]:
            fn = os.path.join(directory, e["name"])
            if not os.path.exists(fn) or _sha256(fn) != e["sha256"]:
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def restore(directory: str, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs). ``shardings``: optional parallel tree of
    NamedShardings — enables cross-mesh (elastic) restore."""
    manifest = json.load(open(os.path.join(directory, MANIFEST)))
    dtypes = {e["name"]: e["dtype"] for e in manifest["leaves"]}
    leaves = jax.tree_util.tree_flatten_with_path(target_tree)
    paths, treedef = leaves[0], leaves[1]
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        name = _leaf_name(path) + ".npy"
        arr = np.load(os.path.join(directory, name))
        true_dt = dtypes.get(name)
        if true_dt is not None and arr.dtype.kind == "u" \
                and true_dt != str(arr.dtype):
            import ml_dtypes  # noqa: F401  (registers bf16/fp8 dtypes)
            arr = arr.view(np.dtype(true_dt))
        if not hasattr(leaf, "shape"):        # python scalar leaf (step/round)
            out.append(type(leaf)(arr))
            continue
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{name}: ckpt {arr.shape} vs target {leaf.shape}"
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, out), manifest["step"]
