"""Render a DIALS telemetry event log into human-readable reports.

Input: a telemetry directory (per-process ``telemetry-p*.jsonl`` files —
merged on the fly if no ``telemetry.jsonl`` exists yet) or a single
JSONL file. Output:

* a per-round table — one line per round with the typed record's phase
  seconds (``repro.obs.metrics.ROUND_FIELDS``), CE, staleness
  distribution, and mesh size;
* an elasticity timeline — every ``host_death`` / ``elastic_reassign``
  event plus the rounds where the mesh size changed, with the
  availability-tax ``mirror_s`` (the per-round host-mirror
  ``fetch_tree`` cost) alongside, so a host-loss incident reads as
  death → replan → shrunken-mesh resume;
* ``--csv FILE`` re-renders the round events through the CSV sink;
* ``--check`` validates instead of rendering (CI's schema gate): the
  log must be parseable and non-empty, every round event must pass
  ``metrics.validate_round``, and each process's round events must be
  monotone in the round index. Exit 1 on any violation.
* ``--check --expect-recovery`` additionally requires the recovery
  story in causal order: a ``host_death``, a generation ≥ 1
  ``rebootstrap`` after it, and a resumed ``run_start`` with
  ``start_round > 0`` — the chaos CI job's gate.

    PYTHONPATH=src python -m tools.telemetry_report experiments/telemetry
    PYTHONPATH=src python -m tools.telemetry_report run.jsonl --check
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List

from repro.obs import metrics, sinks


def load_events(path: str) -> List[Dict]:
    """Events from a telemetry dir (merging per-process files) or a
    single JSONL file, globally ordered."""
    if os.path.isdir(path):
        return sinks.read_jsonl(sinks.merge_dir(path))
    return sorted(sinks.read_jsonl(path),
                  key=lambda e: (e.get("t", 0.0), e.get("proc", 0),
                                 e.get("seq", 0)))


def _fmt(v, width=9) -> str:
    if v is None:
        return "-".rjust(width)
    if isinstance(v, float):
        return f"{v:.3f}".rjust(width)
    return str(v).rjust(width)


def round_table(events: List[Dict]) -> str:
    """One line per round. With several processes, the lowest-numbered
    process that emitted the round speaks for it (every process's round
    records agree on the on-mesh scalars; host timings are local)."""
    per_round: Dict[int, Dict] = {}
    for e in events:
        if e.get("event") != "round":
            continue
        rnd = e["round"]
        if rnd not in per_round or e.get("proc", 0) < \
                per_round[rnd].get("proc", 0):
            per_round[rnd] = e
    if not per_round:
        return "(no round events)"
    cols = ("round", "gs_return", "aip_ce_after", "staleness_max",
            "n_shards", "collect_s", "env_steps_per_s", "aip_s",
            "inner_s", "eval_s", "mirror_s", "round_s")
    widths = {"aip_ce_after": 13, "env_steps_per_s": 15}
    lines = [" ".join(c.rjust(widths.get(c, 9)) for c in cols)]
    for rnd in sorted(per_round):
        e = per_round[rnd]
        cells = []
        for c in cols:
            v = e.get(c)
            cells.append(_fmt(v, widths.get(c, 9)))
        lines.append(" ".join(cells))
    return "\n".join(lines)


def elasticity_timeline(events: List[Dict]) -> str:
    """The host-loss story: deaths, replans, mesh-size changes, and the
    per-round availability tax (``mirror_s``)."""
    lines = []
    prev_shards = None
    for e in events:
        kind = e.get("event")
        if kind == "host_death":
            lines.append(
                f"  round {e.get('round')}: host_death "
                f"dead={e.get('dead_hosts')} "
                f"(detected by p{e.get('proc', 0)}, "
                f"timeout {e.get('timeout_s')}s)")
        elif kind == "elastic_reassign":
            lines.append(
                f"  replan: shards {e.get('old_shards')}->"
                f"{e.get('new_shards')}, dead blocks "
                f"{e.get('dead_blocks')}, moved {e.get('moved')}")
        elif kind == "chaos_inject":
            lines.append(
                f"  round {e.get('round')}: chaos_inject "
                f"kind={e.get('kind')} host={e.get('host')} "
                f"(p{e.get('proc', 0)})")
        elif kind == "recovery_begin":
            lines.append(
                f"  round {e.get('round')}: recovery_begin "
                f"dead={e.get('dead')} -> generation "
                f"{e.get('generation')} (p{e.get('proc', 0)})")
        elif kind == "rebootstrap":
            lines.append(
                f"  rebootstrap: generation {e.get('generation')}, "
                f"{e.get('num_processes')} process(es), "
                f"{e.get('attempts')} attempt(s) (p{e.get('proc', 0)})")
        elif kind == "restore_reshard":
            lines.append(
                f"  restore: step {e.get('step')} re-sharded "
                f"{e.get('old_shards')}->{e.get('new_shards')} shards")
        elif kind == "round":
            shards = e.get("n_shards")
            if prev_shards is not None and shards != prev_shards:
                lines.append(
                    f"  round {e.get('round')}: resumed on "
                    f"{shards}-shard mesh (was {prev_shards}), "
                    f"reassigned={e.get('reassigned')}")
            prev_shards = shards
            if e.get("mirror_s") is not None:
                lines.append(
                    f"  round {e.get('round')}: mirror_s="
                    f"{e['mirror_s']:.3f}s (availability tax, "
                    f"p{e.get('proc', 0)})")
    return "\n".join(lines) if lines else "  (no elasticity events)"


def check(events: List[Dict]) -> List[str]:
    """CI validation: non-empty, schema-clean round events, per-process
    monotone round indices."""
    problems = []
    if not events:
        return ["no events"]
    rounds_by_proc: Dict[int, List[int]] = {}
    n_rounds = 0
    for i, e in enumerate(events):
        if "event" not in e:
            problems.append(f"event {i}: missing 'event' kind")
            continue
        if e["event"] != "round":
            continue
        n_rounds += 1
        for p in metrics.validate_round(e):
            problems.append(f"round event {i} (proc "
                            f"{e.get('proc')}): {p}")
        rounds_by_proc.setdefault(e.get("proc", 0), []).append(e["round"])
    if n_rounds == 0:
        problems.append("no round events")
    for proc, rounds in sorted(rounds_by_proc.items()):
        if rounds != sorted(rounds):
            problems.append(f"proc {proc}: round indices not monotone: "
                            f"{rounds}")
    return problems


def check_recovery(events: List[Dict]) -> List[str]:
    """The chaos job's gate: the log must tell the full recovery story,
    in causal order — a ``host_death`` verdict, then a ``rebootstrap``
    of generation ≥ 1 (the re-executed survivor coming back up), then a
    resumed ``run_start`` with ``start_round > 0`` (training continued
    from the committed checkpoint, not from scratch)."""
    problems = []
    death = next((i for i, e in enumerate(events)
                  if e.get("event") == "host_death"), None)
    if death is None:
        return ["expected a host_death event — no death was detected"]
    begin = next((i for i, e in enumerate(events)
                  if e.get("event") == "recovery_begin" and i > death),
                 None)
    if begin is None:
        problems.append("no recovery_begin after the host_death — the "
                        "supervisor never ran")
    reboot = next((i for i, e in enumerate(events)
                   if e.get("event") == "rebootstrap"
                   and e.get("generation", 0) >= 1 and i > death), None)
    if reboot is None:
        problems.append("no generation>=1 rebootstrap after the "
                        "host_death — the survivor never came back")
        return problems
    resumed = [e for i, e in enumerate(events)
               if e.get("event") == "run_start" and i > reboot]
    if not resumed:
        problems.append("no run_start after the rebootstrap — the "
                        "re-executed survivor never resumed training")
    elif not any(e.get("start_round", 0) > 0 for e in resumed):
        problems.append(
            "resumed run_start has start_round=0 — the survivor "
            "restarted from scratch instead of the committed checkpoint")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="telemetry directory or JSONL file")
    ap.add_argument("--csv", default=None,
                    help="also write round events as CSV to this path")
    ap.add_argument("--check", action="store_true",
                    help="validate only (schema + monotone rounds); "
                         "exit 1 on any problem")
    ap.add_argument("--expect-recovery", action="store_true",
                    help="with --check: additionally require the "
                         "host_death -> rebootstrap -> resumed "
                         "run_start recovery sequence")
    args = ap.parse_args(argv)

    events = load_events(args.path)
    if args.check:
        # violations go through the analyzer's formatter: plain
        # TAG file [rule] lines locally, ::error annotations in CI
        from repro.analysis.report import Finding, emit
        problems = check(events)
        if args.expect_recovery:
            problems += check_recovery(events)
        if emit([Finding(tag="TELEMETRY-INVALID", rule="TelemetrySchema",
                         message=p, file=args.path)
                 for p in problems]):
            return 1
        procs = sorted({e.get("proc", 0) for e in events})
        n_rounds = sum(e.get("event") == "round" for e in events)
        print(f"# telemetry OK: {len(events)} events, {n_rounds} round "
              f"records, processes {procs}")
        return 0

    if args.csv:
        sink = sinks.CsvSink(args.csv)
        sinks.write_events(events, sink)
        sink.close()
        print(f"# wrote {args.csv}")

    print(f"# {args.path}: {len(events)} events from "
          f"{len({e.get('proc', 0) for e in events})} process(es)")
    start = [e for e in events if e.get("event") == "run_start"]
    if start:
        e = start[0]
        print(f"# run: path={e.get('path')} env={e.get('env')} "
              f"shards={e.get('n_shards')} kernels={e.get('kernels')}")
    print("\n== per-round phases ==")
    print(round_table(events))
    print("\n== elasticity timeline ==")
    print(elasticity_timeline(events))
    return 0


if __name__ == "__main__":
    sys.exit(main())
