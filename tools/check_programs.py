"""Static program-contract checker — the CI ``analysis`` gate.

Traces both DIALS drivers across every registered scenario at tiny
sizes (abstractly — no training FLOPs), runs the full
``repro.analysis.contracts`` rule set over the resulting programs, runs
the AST lint pass over the runtime modules, validates the collective
primitive tables against the running jax, and (unless ``--no-recompile``)
executes one tiny run per driver under the compile counter to assert
zero steady-state retraces.

Violations print through ``repro.analysis.report.format_finding`` —
``file:line`` locally, ``::error`` annotations under GitHub Actions.
Exit 1 on any violation.

    PYTHONPATH=src python -m tools.check_programs                # everything
    PYTHONPATH=src python -m tools.check_programs --lint         # lint only
    PYTHONPATH=src python -m tools.check_programs --contracts \
        --scenarios traffic,powergrid --drivers sharded
    PYTHONPATH=src python -m tools.check_programs --selftest     # the
        # deliberately-broken fixtures must FAIL (sanity of the gate)
"""
from __future__ import annotations

import argparse
import os
import sys

# a multi-device mesh must exist before jax initializes; 8 forced host
# devices mirrors the runtime-multidevice CI job (harmless if the env
# var is already set by the caller)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rel(findings):
    """Repo-relativize finding paths so CI annotations land on files."""
    import dataclasses
    out = []
    for f in findings:
        if f.file and os.path.isabs(f.file):
            try:
                f = dataclasses.replace(
                    f, file=os.path.relpath(f.file, REPO_ROOT))
            except ValueError:
                pass
        out.append(f)
    return out


def run_contracts(scenarios, drivers) -> list:
    from repro.analysis import contracts, programs
    progs = programs.all_programs(scenarios or None, drivers)
    print(f"# check_programs: {len(progs)} programs traced "
          f"({', '.join(drivers)} x "
          f"{', '.join(scenarios) if scenarios else 'all scenarios'})")
    return contracts.run_rules(progs)


def run_lint() -> list:
    from repro.analysis import lint
    targets = lint.default_targets(os.path.join(REPO_ROOT, "src",
                                                "repro"))
    print(f"# check_programs: linting {len(targets)} runtime modules")
    return lint.lint_paths(targets)


def run_tables() -> list:
    from repro.analysis.report import Finding
    from repro.distributed import runtime
    try:
        runtime.validate_collective_tables()
    except AssertionError as e:
        return [Finding(tag="CONTRACT-VIOLATION", rule="PrimTables",
                        message=str(e))]
    return []


def run_recompile() -> list:
    """One tiny run per driver under the compile counter: zero
    retraces after the warm-up round (3 rounds so the steady state is
    observed twice)."""
    import jax
    from repro.analysis import programs, recompile
    findings = []
    for driver, kw in (("loop", dict(shards=1)), ("sharded", {})):
        trainer = programs.tiny_trainer("traffic", outer_rounds=3, **kw)
        counts = []
        with recompile.CompileCounter() as cc:
            trainer.run(jax.random.PRNGKey(0),
                        log=lambda rec: counts.append(cc.count))
        print(f"# check_programs: {driver} driver compile counts "
              f"per round: {counts}")
        findings.extend(recompile.check_steady_state(
            counts, what=f"{driver} driver"))
    return findings


def run_selftest() -> int:
    """The gate must still be able to fail: deliberately-broken fixtures
    (a psum smuggled into a train body, an unused donated buffer, a
    reused PRNG key) must each produce a finding with provenance."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import contracts, lint, walker
    from repro.distributed import runtime

    failures = []

    mesh = runtime.shard_mesh(1)
    smuggled = jax.make_jaxpr(runtime.shard_map_nocheck(
        lambda x: x + jax.lax.psum(x.sum(), runtime.SHARD_AXIS),
        mesh, in_specs=(P(runtime.SHARD_AXIS),),
        out_specs=P(runtime.SHARD_AXIS)))(jnp.ones((4, 2)))
    body = runtime.find_shard_map_jaxprs(smuggled)[0]
    found = contracts.run_rules(
        [contracts.Program(name="selftest/psum-in-train-body",
                           roles=("train_body",), jaxpr=body)])
    if not (found and found[0].line and "psum" in found[0].message):
        failures.append("psum-in-train-body fixture did not fail "
                        "with provenance")

    def unused_donation(carry, x):
        return x * 2.0                     # carry never aliased
    found = contracts.DonationUsed().check(contracts.Program(
        name="selftest/unused-donation", roles=("donated",),
        fn=unused_donation,
        args=(jax.ShapeDtypeStruct((8,), jnp.float32),
              jax.ShapeDtypeStruct((8,), jnp.float32)),
        donate_argnums=(0,)))
    if not found:
        failures.append("unused-donation fixture did not fail")

    found = lint.lint_source(
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.uniform(key, (3,))\n"
        "    return a + b\n", filename="selftest_reuse.py")
    if not (found and found[0].rule == "prng-reuse" and found[0].line):
        failures.append("reused-PRNG-key fixture did not fail with "
                        "provenance")

    site = walker.sites(smuggled, ("psum",))
    if not (site and site[0].path and site[0].file):
        failures.append("walker lost path/source provenance")

    for msg in failures:
        print(f"SELFTEST-FAIL {msg}")
    print("# check_programs --selftest: "
          + ("FAIL" if failures else "OK (all broken fixtures fail)"))
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--contracts", action="store_true",
                    help="run only the jaxpr contract pass")
    ap.add_argument("--lint", action="store_true",
                    help="run only the AST lint pass")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate fails on broken fixtures")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated env names (default: all "
                         "registered)")
    ap.add_argument("--drivers", default="loop,sharded",
                    help="comma-separated driver subset")
    ap.add_argument("--no-recompile", action="store_true",
                    help="skip the steady-state recompile check (the "
                         "one pass that executes real rounds)")
    args = ap.parse_args()

    if args.selftest:
        return run_selftest()

    from repro.analysis.report import emit
    everything = not (args.contracts or args.lint)
    findings = []
    if everything or args.contracts:
        findings += run_tables()
        findings += run_contracts(
            [s for s in args.scenarios.split(",") if s],
            [d for d in args.drivers.split(",") if d])
        if not args.no_recompile:
            findings += run_recompile()
    if everything or args.lint:
        findings += run_lint()
    n = emit(_rel(findings))
    if n:
        print(f"# check_programs: {n} violation(s)")
        return 1
    print("# check_programs: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
